//! Pathfinder (§4.3.1.4): dynamic programming, integer min-accumulate
//! over a 2D grid with row-to-row dependency.
//!
//! Variant derivations (Table 4-6):
//!
//! * **None/NDR** — Rodinia original: 256-wide blocks, pyramid 10.
//! * **None/SWI** — column loop in-kernel (II=1), row loop on the host.
//! * **Basic/NDR** — wg 1024, SIMD 16, pipeline ×2, pyramid 32.
//! * **Basic/SWI** — branch-hoisted + unroll 64.
//! * **Advanced/NDR** — Hotspot-style local-memory rework: block 8192,
//!   SIMD 16 × unroll 2, pyramid 92.
//! * **Advanced/SWI** — shift-register design, block 32768, unroll 32,
//!   pyramid fused in-pipeline; unaligned overlapped reads and a single
//!   hot buffer limit DDR efficiency (§4.3.1.4's analysis).

use crate::device::FpgaDevice;
use crate::perfmodel::fmax::CriticalPath;
use crate::perfmodel::memory::{AccessPattern, MemorySpec};
use crate::perfmodel::pipeline::{KernelClass, PipelineSpec};
use crate::rodinia::common::{
    rows_with_speedup, usage_frac, BenchmarkRow, KernelDesign, OptLevel, VariantKey,
};

/// Input (§4.3.1.4): 1,000,000 columns × 1,000 rows.
pub const COLS: u64 = 1_000_000;
pub const ROWS: u64 = 1_000;

fn cells() -> u64 {
    COLS * ROWS
}

pub fn designs(dev: &FpgaDevice) -> Vec<KernelDesign> {
    let mut v = Vec::new();

    // --- None / NDR: block 256, pyramid 10 ---
    let red = |bsize: f64, pyr: f64| bsize / (bsize - 2.0 * pyr);
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "pathfinder-none-ndr".into(),
            depth: 500,
            trip_count: (cells() as f64 * red(256.0, 10.0)) as u64,
            // work-group pipelining hides the single barrier here
            class: KernelClass::NdRange { barriers: 0 },
            // wall streamed every row; result row amortized over pyramid
            bytes_per_iter: 4.4,
            parallelism: 1,
            memory: MemorySpec::with_pattern(AccessPattern::StreamingUnaligned),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.20, 0.16, 0.04, 0.02),
        critical_path: CriticalPath::Clean,
        flat: false,
        bw_utilization: 0.35,
    });

    // --- None / SWI: row loop on host -> refill per row ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::None, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "pathfinder-none-swi".into(),
            depth: 400,
            trip_count: COLS,
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 4.4, // wall streamed; prev row cached on-chip
            parallelism: 1,
            memory: MemorySpec::streaming(),
            invocations: ROWS,
        }],
        usage: usage_frac(dev, 0.20, 0.16, 0.05, 0.005),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.50,
    });

    // --- Basic / NDR: wg 1024, SIMD 16, CU x2, pyramid 32 ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "pathfinder-basic-ndr".into(),
            depth: 700,
            trip_count: (cells() as f64 * red(1024.0, 32.0)) as u64,
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 4.2,
            parallelism: 32,
            memory: MemorySpec::with_pattern(AccessPattern::StreamingUnaligned),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.54, 0.80, 0.35, 0.03),
        critical_path: CriticalPath::BarrierMux,
        flat: false,
        bw_utilization: 0.60,
    });

    // --- Basic / SWI: unroll 64, but refills per row remain ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Basic, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "pathfinder-basic-swi".into(),
            depth: 900,
            trip_count: COLS,
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 4.2,
            parallelism: 64,
            // unroll-64 keeps many narrow ports despite register hoisting
            memory: MemorySpec::with_pattern(AccessPattern::Strided),
            invocations: ROWS,
        }],
        usage: usage_frac(dev, 0.40, 0.32, 0.20, 0.005),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.60,
    });

    // --- Advanced / NDR: block 8192, SIMD16 x unroll2, pyramid 92 ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "NDR" },
        pipelines: vec![PipelineSpec {
            name: "pathfinder-adv-ndr".into(),
            depth: 1_200,
            trip_count: (cells() as f64 * red(8192.0, 92.0)) as u64,
            class: KernelClass::NdRange { barriers: 1 },
            bytes_per_iter: 4.1,
            parallelism: 32,
            // work-group pipelining overlaps the two banks' streams,
            // recovering the alignment losses (§4.3.1.4's explanation of
            // the NDR kernel's win)
            memory: MemorySpec::with_pattern(AccessPattern::Streaming),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.44, 0.55, 0.32, 0.02),
        critical_path: CriticalPath::Clean,
        flat: false,
        bw_utilization: 0.55,
    });

    // --- Advanced / SWI: shift registers, block 32768, unroll 32 ---
    v.push(KernelDesign {
        key: VariantKey { level: OptLevel::Advanced, kind: "SWI" },
        pipelines: vec![PipelineSpec {
            name: "pathfinder-adv-swi".into(),
            depth: 1_500,
            trip_count: (cells() as f64 * red(32768.0, 92.0)) as u64,
            class: KernelClass::SingleWorkItem { stalls: 0 },
            bytes_per_iter: 4.1,
            parallelism: 32,
            // unaligned overlapped reads + a single hot buffer that
            // cannot keep both banks busy (§4.3.1.4)
            memory: MemorySpec::with_pattern(AccessPattern::StreamingUnaligned)
                .bank_limited(0.8),
            invocations: 1,
        }],
        usage: usage_frac(dev, 0.34, 0.21, 0.07, 0.005),
        critical_path: CriticalPath::Clean,
        flat: true,
        bw_utilization: 0.50,
    });

    v
}

pub fn simulate(dev: &FpgaDevice) -> Vec<BenchmarkRow> {
    rows_with_speedup(&designs(dev), dev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::stratix_v;

    #[test]
    fn table_4_6_shape() {
        let rows = simulate(&stratix_v());
        let t = |i: usize| rows[i].report.seconds;
        assert!(t(1) < t(0) * 1.5, "none variants comparable");
        assert!(t(2) < t(1) && t(3) < t(1), "basic improves");
        assert!(t(4) < t(2) && t(5) < t(3), "advanced improves further");
        assert!(t(4) < t(5), "adv/NDR narrowly wins (work-group pipelining)");
        assert!(rows[4].speedup > 8.0, "speedup {}", rows[4].speedup);
    }

    #[test]
    fn advanced_swi_higher_fmax_lower_bram() {
        // Table 4-6: the SWI design clocks higher (278 vs 240 MHz) with
        // far less Block RAM despite a 4x bigger block.
        let rows = simulate(&stratix_v());
        assert!(rows[5].report.fmax_mhz > rows[4].report.fmax_mhz);
        assert!(rows[5].report.m20k_blocks_frac < rows[4].report.m20k_blocks_frac);
    }

    #[test]
    fn subsecond_advanced_times() {
        let rows = simulate(&stratix_v());
        assert!(rows[4].report.seconds < 1.0 && rows[5].report.seconds < 1.0);
    }
}
