//! Property-testing helpers (no proptest in the offline dependency set).
//!
//! A deterministic xorshift PRNG plus a tiny `for_cases` driver: generate
//! `n` random cases, run the property, and on failure report the seed so
//! the case can be replayed.

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i32
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Vec of random f32 in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vec of random i32 in [lo, hi].
    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.i32_in(lo, hi)).collect()
    }
}

/// True when `artifacts/` is present *and* the linked XLA backend can
/// actually compile one — probed once per process and cached.
///
/// The vendored `xla` shim (`vendor/xla`) marshals host data but
/// cannot compile HLO, so on runners without the native
/// `xla_extension` backend every artifact-driven test must skip
/// instead of failing tier-1.  See [`crate::require_backend!`].
pub fn backend_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        let rt = match crate::runtime::Runtime::open("artifacts") {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("backend probe: no artifacts/ manifest ({e:#})");
                return false;
            }
        };
        let Some(name) = rt.registry().names().into_iter().next() else {
            eprintln!("backend probe: artifact manifest is empty");
            return false;
        };
        match rt.executable(&name) {
            Ok(_) => true,
            Err(e) => {
                eprintln!("backend probe: compiling '{name}' failed ({e:#})");
                false
            }
        }
    })
}

/// Skip the calling test (early-return) unless
/// [`backend_available`](crate::testutil::backend_available) holds.
/// Every artifact-driven test opens with this guard so the tier-1
/// gate runs green on machines that only have the vendored xla shim,
/// while still exercising the full suite wherever the native backend
/// is installed.
#[macro_export]
macro_rules! require_backend {
    () => {
        if !$crate::testutil::backend_available() {
            eprintln!("SKIP: artifacts/ or the native XLA backend is unavailable");
            return;
        }
    };
}

/// Run `prop` over `n` generated cases; panics with the failing seed.
pub fn for_cases(n: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..n {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Max |a-b| over two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// assert_allclose with mixed absolute/relative tolerance.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{what}: mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let v = rng.u64_in(3, 9);
            assert!((3..=9).contains(&v));
            let f = rng.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
            let i = rng.i32_in(-5, 5);
            assert!((-5..=5).contains(&i));
        }
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6, "bad")
        });
        assert!(r.is_err());
    }
}
