//! Rodinia sweep: the Chapter 4 experiment end to end.
//!
//! For each of the six benchmarks: run the *functional* workload through
//! the AOT compute units (small inputs, verified against oracles), then
//! print the simulated FPGA variant table (None/Basic/Advanced ×
//! NDR/SWI) for Stratix V — the data behind Tables 4-3 … 4-8.
//!
//! Run: `cargo run --release --example rodinia_sweep`

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::{apps, reference, stencil_runner};
use fpga_hpc::device::stratix_v;
use fpga_hpc::runtime::Runtime;
use fpga_hpc::testutil::{assert_allclose, max_abs_diff, Rng};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    let mut rng = Rng::new(99);

    // --- functional runs (small but real workloads) ---
    println!("functional verification through PJRT:");

    let n = 512;
    let temp = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 60.0, 90.0) };
    let power = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 0.0, 1.0) };
    let (hs, m) = stencil_runner::run_stencil2d(&rt, "hotspot2d", temp.clone(), Some(&power), 8)?;
    let hs_want = reference::hotspot2d(temp, &power, reference::HotspotParams::default(), 8);
    assert_allclose(&hs.data, &hs_want.data, 1e-4, 1e-3, "hotspot");
    println!("  hotspot      OK  ({})", m.summary());

    let rows = 33;
    let cols = 8192;
    let wall: Vec<Vec<i32>> = (0..rows).map(|_| rng.vec_i32(cols, 0, 10)).collect();
    let (pf, m) = apps::run_pathfinder(&rt, &wall)?;
    assert_eq!(pf, reference::pathfinder(&wall), "pathfinder mismatch");
    println!("  pathfinder   OK  ({})", m.summary());

    let nn = 256;
    let refm: Vec<Vec<i32>> = (0..=nn).map(|_| rng.vec_i32(nn + 1, -5, 15)).collect();
    let (nw, m) = apps::run_nw(&rt, &refm, 10)?;
    assert_eq!(nw, reference::nw(&refm, 10), "nw mismatch");
    println!("  nw           OK  ({})", m.summary());

    let img = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 0.5, 2.0) };
    let (sr, m) = apps::run_srad(&rt, img.clone(), 2)?;
    let sr_want = reference::srad(img, 0.5, 2);
    println!("  srad         OK  max|err|={:.1e} ({})", max_abs_diff(&sr.data, &sr_want.data), m.summary());

    let nl = 192;
    let a: Vec<Vec<f32>> = (0..nl)
        .map(|i| (0..nl).map(|j| rng.f32_in(-1.0, 1.0) + if i == j { nl as f32 } else { 0.0 }).collect())
        .collect();
    let (lu, m) = apps::run_lud(&rt, &a)?;
    let lu_want = reference::lud(&a);
    let mut err = 0f32;
    for i in 0..nl {
        err = err.max(max_abs_diff(&lu[i], &lu_want[i]));
    }
    anyhow::ensure!(err < 1e-2, "lud mismatch: {err}");
    println!("  lud          OK  max|err|={err:.1e} ({})", m.summary());

    // --- simulated variant sweep ---
    println!("\nsimulated Stratix V variant sweep (Tables 4-3 .. 4-8):");
    let dev = stratix_v();
    for (name, rows) in fpga_hpc::rodinia::all_benchmarks(&dev) {
        println!("{name}:");
        for r in rows {
            println!(
                "  {:<14} {:>10.3}s  {:>6.1}W  fmax {:>3.0}MHz  speedup {:>8.2}{}",
                r.report.name, r.report.seconds, r.report.power_w,
                r.report.fmax_mhz, r.speedup,
                if r.report.memory_bound { "  [BW]" } else { "" },
            );
        }
    }
    Ok(())
}
