//! Rodinia sweep: the Chapter 4 experiment end to end.
//!
//! For each of the six benchmarks: run the *functional* workload through
//! the AOT compute units via the Session API (small inputs, verified
//! against oracles), then print the simulated FPGA variant table
//! (None/Basic/Advanced × NDR/SWI) for Stratix V — the data behind
//! Tables 4-3 … 4-8.
//!
//! Run: `cargo run --release --example rodinia_sweep`

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::reference;
use fpga_hpc::coordinator::session::{Session, Workload};
use fpga_hpc::device::stratix_v;
use fpga_hpc::testutil::{assert_allclose, max_abs_diff, Rng};

fn main() -> anyhow::Result<()> {
    let session = Session::builder().artifacts("artifacts").lanes(2).build()?;
    let mut rng = Rng::new(99);

    // --- functional runs (small but real workloads) ---
    println!("functional verification through PJRT:");

    let n = 512;
    let temp = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 60.0, 90.0) };
    let power = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 0.0, 1.0) };
    let report =
        session.run(Workload::stencil2d("hotspot2d", temp.clone(), Some(power.clone()), 8))?;
    let m = report.metrics.clone();
    let hs = report.into_output().into_grid2d().unwrap();
    let hs_want = reference::hotspot2d(temp, &power, reference::HotspotParams::default(), 8);
    assert_allclose(&hs.data, &hs_want.data, 1e-4, 1e-3, "hotspot");
    println!("  hotspot      OK  ({})", m.summary());

    let rows = 33;
    let cols = 8192;
    let wall: Vec<Vec<i32>> = (0..rows).map(|_| rng.vec_i32(cols, 0, 10)).collect();
    let report = session.run(Workload::pathfinder(wall.clone()))?;
    let m = report.metrics.clone();
    let pf = report.into_output().into_row().unwrap();
    assert_eq!(pf, reference::pathfinder(&wall), "pathfinder mismatch");
    println!("  pathfinder   OK  ({})", m.summary());

    let nn = 256;
    let refm: Vec<Vec<i32>> = (0..=nn).map(|_| rng.vec_i32(nn + 1, -5, 15)).collect();
    let report = session.run(Workload::nw(refm.clone(), 10))?;
    let m = report.metrics.clone();
    let nw = report.into_output().into_score_matrix().unwrap();
    assert_eq!(nw, reference::nw(&refm, 10), "nw mismatch");
    println!("  nw           OK  ({})", m.summary());

    let img = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 0.5, 2.0) };
    let report = session.run(Workload::srad(img.clone(), 2))?;
    let m = report.metrics.clone();
    let sr = report.into_output().into_grid2d().unwrap();
    let sr_want = reference::srad(img, 0.5, 2);
    let sr_err = max_abs_diff(&sr.data, &sr_want.data);
    println!("  srad         OK  max|err|={sr_err:.1e} ({})", m.summary());

    let nl = 192;
    let a: Vec<Vec<f32>> = (0..nl)
        .map(|i| {
            (0..nl)
                .map(|j| rng.f32_in(-1.0, 1.0) + if i == j { nl as f32 } else { 0.0 })
                .collect()
        })
        .collect();
    let report = session.run(Workload::lud(a.clone()))?;
    let m = report.metrics.clone();
    let lu = report.into_output().into_matrix().unwrap();
    let lu_want = reference::lud(&a);
    let mut err = 0f32;
    for i in 0..nl {
        err = err.max(max_abs_diff(&lu[i], &lu_want[i]));
    }
    anyhow::ensure!(err < 1e-2, "lud mismatch: {err}");
    println!("  lud          OK  max|err|={err:.1e} ({})", m.summary());

    // --- simulated variant sweep ---
    println!("\nsimulated Stratix V variant sweep (Tables 4-3 .. 4-8):");
    let dev = stratix_v();
    for (name, rows) in fpga_hpc::rodinia::all_benchmarks(&dev) {
        println!("{name}:");
        for r in rows {
            println!(
                "  {:<14} {:>10.3}s  {:>6.1}W  fmax {:>3.0}MHz  speedup {:>8.2}{}",
                r.report.name, r.report.seconds, r.report.power_w,
                r.report.fmax_mhz, r.speedup,
                if r.report.memory_bound { "  [BW]" } else { "" },
            );
        }
    }
    Ok(())
}
