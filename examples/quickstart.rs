//! Quickstart: the smallest end-to-end trip through all three layers.
//!
//! Loads the AOT-compiled Pallas diffusion kernel (L1/L2, built once by
//! `make artifacts`), streams a small grid through the Rust coordinator
//! (L3) via the Session builder API, verifies against the native
//! reference, and asks the analytic FPGA simulator what the same
//! workload would do on the thesis's devices.
//!
//! Run: `cargo run --release --example quickstart`

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::reference;
use fpga_hpc::coordinator::session::{Session, Workload};
use fpga_hpc::device::{arria_10, stratix_v};
use fpga_hpc::stencil::config::{diffusion2d, Workload as SimWorkload};
use fpga_hpc::stencil::tuner::tune;
use fpga_hpc::testutil::{max_abs_diff, Rng};

fn main() -> anyhow::Result<()> {
    // --- functional path: PJRT execution of the Pallas artifact ---
    let session = Session::builder().artifacts("artifacts").lanes(2).build()?;
    let n = 512;
    let steps = 8;
    let mut rng = Rng::new(1);
    let data = rng.vec_f32(n * n, 0.0, 1.0);
    let grid = Grid2D { ny: n, nx: n, data };

    println!("[1/3] streaming {n}x{n} diffusion grid for {steps} steps through PJRT...");
    let report = session.run(Workload::stencil2d("diffusion2d_r1", grid.clone(), None, steps))?;
    anyhow::ensure!(report.ok(), "run reported block faults: {:?}", report.first_fault());
    println!("      {}", report.metrics.summary());

    println!("[2/3] verifying against the native Rust oracle...");
    let spec = session.pool().registry().get("diffusion2d_r1").unwrap().clone();
    let coeffs: Vec<f32> = spec.meta_f64_list("coeffs")?.iter().map(|&v| v as f32).collect();
    let out = report
        .into_output()
        .into_grid2d()
        .ok_or_else(|| anyhow::anyhow!("stencil run produced no grid"))?;
    let want = reference::diffusion2d(grid, &coeffs, steps as usize);
    let err = max_abs_diff(&out.data, &want.data);
    println!("      max |err| = {err:.2e}");
    anyhow::ensure!(err < 1e-5, "verification failed");

    println!("[3/3] simulating the same stencil on the thesis's FPGAs...");
    let shape = diffusion2d(1);
    let work = SimWorkload { extent: n as u64, steps };
    for dev in [stratix_v(), arria_10()] {
        let res = tune(&shape, &work, &dev);
        println!(
            "      {:<18} best {:<24} -> {:>7.1} GFLOP/s at {:>3.0} MHz, {:>4.1} W",
            dev.name, res.best.config.label(), res.best.gflops,
            res.best.fmax_mhz, res.best.power_w,
        );
    }
    println!("quickstart OK");
    Ok(())
}
