//! High-order stencil scenario: the Ch. 5 headline experiment in miniature.
//!
//! Runs first- to fourth-order 2D diffusion both *functionally* (streamed
//! through the AOT Pallas compute units via the Session API, verified
//! against the oracle) and *on the simulated FPGAs* (tuned accelerator
//! configurations), printing a combined report — the reproduction of
//! Figs. 5-9/5-10's sweep.
//!
//! Run: `cargo run --release --example stencil_diffusion`

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::reference;
use fpga_hpc::coordinator::session::{Session, Workload};
use fpga_hpc::device::arria_10;
use fpga_hpc::stencil::config::{default_workload, diffusion2d};
use fpga_hpc::stencil::tuner::tune;
use fpga_hpc::testutil::{max_abs_diff, Rng};

fn main() -> anyhow::Result<()> {
    let session = Session::builder().artifacts("artifacts").lanes(2).build()?;
    let a10 = arria_10();
    println!(
        "{:<16} {:>10} {:>12} {:>12} {:>10} {:>12}",
        "stencil", "max|err|", "exec GCell/s", "sim GFLOP/s", "sim GCell/s", "sim config"
    );
    for radius in 1..=4u32 {
        let artifact = format!("diffusion2d_r{radius}");
        let spec = session.pool().registry().get(&artifact).unwrap().clone();
        let t_fused = spec.meta_u64("steps")?;
        let raw = spec.meta_f64_list("coeffs")?;
        let coeffs: Vec<f32> = raw.iter().map(|&v| v as f32).collect();

        // functional: 2 fused passes over a 512^2 grid
        let n = 512;
        let steps = 2 * t_fused;
        let mut rng = Rng::new(radius as u64);
        let grid = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 0.0, 1.0) };
        let report =
            session.run(Workload::stencil2d(artifact.clone(), grid.clone(), None, steps))?;
        anyhow::ensure!(report.ok(), "r={radius} run reported block faults");
        let metrics = report.metrics.clone();
        let out = report
            .into_output()
            .into_grid2d()
            .ok_or_else(|| anyhow::anyhow!("stencil run produced no grid"))?;
        let want = reference::diffusion2d(grid, &coeffs, steps as usize);
        let err = max_abs_diff(&out.data, &want.data);
        anyhow::ensure!(err < 1e-5, "r={radius} verification failed: {err}");

        // simulated: tuned Arria 10 accelerator
        let shape = diffusion2d(radius);
        let res = tune(&shape, &default_workload(2), &a10);
        println!(
            "{:<16} {:>10.2e} {:>12.3} {:>12.1} {:>10.2} {:>14}",
            shape.name, err, metrics.gcell_per_sec(),
            res.best.gflops, res.best.gcells, res.best.config.label(),
        );
    }
    Ok(())
}
