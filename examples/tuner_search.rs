//! Model-driven configuration tuning — the §5.4 workflow.
//!
//! Enumerates the (par, T, bsize) space for each stencil benchmark on
//! each FPGA, prunes by the area model, ranks by predicted GFLOP/s, and
//! prints the winner plus the pruning ratio — the step that replaces
//! multi-day Quartus sweeps in the thesis.
//!
//! Run: `cargo run --release --example tuner_search`

use fpga_hpc::device::{arria_10, stratix_10, stratix_v};
use fpga_hpc::stencil::config::{
    default_workload, diffusion2d, diffusion3d, hotspot2d_shape, hotspot3d_shape,
};
use fpga_hpc::stencil::tuner::tune;

fn main() {
    let shapes = [
        (diffusion2d(1), 2), (diffusion2d(2), 2), (diffusion2d(3), 2), (diffusion2d(4), 2),
        (diffusion3d(1), 3), (diffusion3d(2), 3), (diffusion3d(3), 3), (diffusion3d(4), 3),
        (hotspot2d_shape(), 2), (hotspot3d_shape(), 3),
    ];
    for dev in [stratix_v(), arria_10(), stratix_10()] {
        println!("=== {} ===", dev.name);
        println!(
            "{:<18} {:>24} {:>9} {:>9} {:>9} {:>8} {:>6} {:>6}",
            "stencil", "best config", "GFLOP/s", "GCell/s", "fmax", "power", "DSP%", "M20K%"
        );
        for (shape, dims) in &shapes {
            let work = default_workload(*dims);
            let res = tune(shape, &work, &dev);
            let b = &res.best;
            println!(
                "{:<18} {:>24} {:>9.1} {:>9.2} {:>6.0}MHz {:>7.1}W {:>5.0}% {:>5.0}%  ({}/{} feasible){}",
                shape.name,
                b.config.label(),
                b.gflops,
                b.gcells,
                b.fmax_mhz,
                b.power_w,
                b.budget.dsp * 100.0,
                b.budget.m20k_blocks * 100.0,
                res.ranked.len(),
                res.enumerated,
                if b.memory_bound { " [BW-bound]" } else { "" },
            );
        }
        println!();
    }
}
