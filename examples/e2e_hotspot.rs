//! End-to-end driver (DESIGN.md §6): the full system on a real workload.
//!
//! Hotspot-style thermal simulation of a 1024×1024 die for 96 time steps:
//! the grid is streamed through the AOT Pallas compute unit in overlapped
//! spatial blocks with temporal blocking T=4, exactly the accelerator
//! architecture of Ch. 5 with Rodinia's Hotspot physics (Ch. 4).
//!
//! Proves all layers compose:
//!   L1  pallas hotspot2d kernel (fused steps, clamp-boundary restore)
//!   L2  jax lowering -> artifacts/hotspot2d.hlo.txt
//!   L3  rust coordinator: Session front door, halo extraction, pipelined
//!       marshalling, multi-lane PJRT execution, write-back — Python
//!       nowhere at run time.
//!
//! Reports: verification vs the native oracle, wallclock throughput of
//! the real execution, coordinator overhead, and the simulated timings
//! for the same workload on the thesis's FPGAs.  Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example e2e_hotspot`

use fpga_hpc::coordinator::grid::Grid2D;
use fpga_hpc::coordinator::reference;
use fpga_hpc::coordinator::session::{Session, Workload};
use fpga_hpc::device::{arria_10, stratix_v};
use fpga_hpc::stencil::config::{hotspot2d_shape, Workload as SimWorkload};
use fpga_hpc::stencil::tuner::tune;
use fpga_hpc::testutil::{max_abs_diff, Rng};

fn main() -> anyhow::Result<()> {
    let n = 1024usize;
    let steps = 96u64;
    println!("=== e2e: Hotspot thermal simulation, {n}x{n} die, {steps} steps ===");

    let session = Session::builder().artifacts("artifacts").lanes(2).build()?;
    let mut rng = Rng::new(2024);
    // initial temperature field ~70-90C with a hot region, uniform power
    let temp = Grid2D::from_fn(n, n, |y, x| {
        let base = 70.0 + 10.0 * ((y as f32 / n as f32) * std::f32::consts::PI).sin();
        base + if (300..600).contains(&y) && (300..600).contains(&x) { 8.0 } else { 0.0 }
    });
    let power = Grid2D { ny: n, nx: n, data: rng.vec_f32(n * n, 0.0, 0.8) };

    // --- real execution through the three-layer stack ---
    let report = session.run(Workload::stencil2d(
        "hotspot2d",
        temp.clone(),
        Some(power.clone()),
        steps,
    ))?;
    anyhow::ensure!(report.ok(), "run reported block faults: {:?}", report.first_fault());
    println!("\n[execution]");
    println!("  {}", report.metrics.summary());
    println!(
        "  wallclock {:.3}s  coordinator overhead {:.1}%",
        report.elapsed.as_secs_f64(),
        100.0 * report.metrics.overhead_frac(),
    );
    let stats = session.pool().stats();
    println!(
        "  runtime: {} executions, compile {:.0}ms, execute {:.0}ms, marshal {:.0}ms",
        stats.executions, stats.compile_ms, stats.execute_ms, stats.marshal_ms,
    );
    let out = report
        .into_output()
        .into_grid2d()
        .ok_or_else(|| anyhow::anyhow!("stencil run produced no grid"))?;

    // --- verification ---
    println!("\n[verification]");
    let t0 = std::time::Instant::now();
    let params = reference::HotspotParams::default();
    let want = reference::hotspot2d(temp, &power, params, steps as usize);
    let ref_wall = t0.elapsed();
    let err = max_abs_diff(&out.data, &want.data);
    println!("  native single-thread reference: {:.3}s", ref_wall.as_secs_f64());
    println!("  max |err| = {err:.2e}");
    anyhow::ensure!(err < 2e-3, "verification failed");
    // physical sanity: temperatures bounded, hot region warmer
    let avg: f32 = out.data.iter().sum::<f32>() / out.data.len() as f32;
    println!("  mean temperature {avg:.2} C (bounded, ambient pull 80 C)");
    anyhow::ensure!(avg > 40.0 && avg < 120.0);

    // --- simulated FPGA timings for the same workload ---
    println!("\n[simulated FPGAs, same workload]");
    let shape = hotspot2d_shape();
    let work = SimWorkload { extent: n as u64, steps };
    for dev in [stratix_v(), arria_10()] {
        let res = tune(&shape, &work, &dev);
        println!(
            "  {:<18} {:<24} {:>8.4}s  {:>7.1} GFLOP/s  {:>5.1} W  ({})",
            dev.name, res.best.config.label(), res.best.seconds,
            res.best.gflops, res.best.power_w,
            if res.best.memory_bound { "BW-bound" } else { "compute-bound" },
        );
    }
    println!("\ne2e_hotspot OK");
    Ok(())
}
