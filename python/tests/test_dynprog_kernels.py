"""Pathfinder and NW pallas kernels vs the sequential oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import dynprog, ref


def randi(shape, seed=0, lo=0, hi=10):
    rs = np.random.RandomState(seed)
    return rs.randint(lo, hi, size=shape).astype(np.int32)


# ---------------------------------------------------------------------------
# Pathfinder
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(width=st.sampled_from([16, 33, 64]), fused=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_pathfinder_tile_matches_ref(width, fused, seed):
    """Interior of the fused-rows kernel equals row-by-row accumulation.

    The halo'd tile is an excerpt of a wider grid, so clamp-vs-interior
    differences stay confined to the consumed halo.
    """
    padded = width + 2 * fused
    wall = randi((fused + 1, padded), seed)
    prev = wall[0]
    k = dynprog.pathfinder_tile(width, fused)
    out = k(prev, wall[1:])

    acc = jnp.asarray(prev)
    for t in range(1, fused + 1):
        acc = ref.pathfinder_row(acc, jnp.asarray(wall[t]))
    want = np.asarray(acc)[fused:padded - fused]
    np.testing.assert_array_equal(np.asarray(out), want)


def test_pathfinder_full_grid_blocked():
    """Blocked pathfinder over a full grid equals the oracle, including the
    grid-edge clamp the coordinator applies when filling halos."""
    rows, cols, fused, bw = 8, 48, 4, 16
    wall = randi((rows + 1, cols), 3)
    acc = wall[0].copy()
    for base in range(0, rows, fused):
        nxt = np.empty_like(acc)
        for x0 in range(0, cols, bw):
            # coordinator-style halo fill with edge clamp
            idx = np.clip(np.arange(x0 - fused, x0 + bw + fused), 0, cols - 1)
            prev = acc[idx]
            rowsl = wall[base + 1: base + 1 + fused][:, idx]
            out = dynprog.pathfinder_tile(bw, fused)(
                prev.astype(np.int32), rowsl.astype(np.int32))
            nxt[x0:x0 + bw] = np.asarray(out)
        acc = nxt
    want = np.asarray(ref.pathfinder(jnp.asarray(wall)))
    np.testing.assert_array_equal(acc, want)


# ---------------------------------------------------------------------------
# Needleman-Wunsch
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([5, 16, 31]), penalty=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_nw_tile_single_block(n, penalty, seed):
    """One NW block with oracle borders equals the oracle's interior."""
    full = ref.nw(jnp.asarray(randi((n + 1, n + 1), seed, -5, 15)), penalty)
    full = np.asarray(full)
    refm = randi((n + 1, n + 1), seed, -5, 15)
    # recompute oracle to bind refm (same seed => same values)
    full = np.asarray(ref.nw(jnp.asarray(refm), penalty))

    top = full[0, 1:]
    left = full[1:, 0]
    corner = full[0:1, 0]
    k = dynprog.nw_tile(n, n, penalty)
    out = k(top.astype(np.int32), left.astype(np.int32),
            corner.astype(np.int32), refm[1:, 1:].astype(np.int32))
    np.testing.assert_array_equal(np.asarray(out), full[1:, 1:])


def test_nw_blocked_decomposition():
    """2x2 block decomposition stitches to the full oracle matrix."""
    b, penalty, seed = 8, 4, 11
    n = 2 * b
    refm = randi((n + 1, n + 1), seed, -5, 15)
    want = np.asarray(ref.nw(jnp.asarray(refm), penalty))

    score = np.zeros((n + 1, n + 1), dtype=np.int32)
    score[0, :] = want[0, :]
    score[:, 0] = want[:, 0]
    k = dynprog.nw_tile(b, b, penalty)
    for bi in range(2):
        for bj in range(2):
            r0, c0 = 1 + bi * b, 1 + bj * b
            top = score[r0 - 1, c0:c0 + b]
            left = score[r0:r0 + b, c0 - 1]
            corner = score[r0 - 1:r0, c0 - 1]
            out = k(top.astype(np.int32), left.astype(np.int32),
                    corner.astype(np.int32),
                    refm[r0:r0 + b, c0:c0 + b].astype(np.int32))
            score[r0:r0 + b, c0:c0 + b] = np.asarray(out)
    np.testing.assert_array_equal(score, want)
