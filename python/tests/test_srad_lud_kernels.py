"""SRAD and LUD pallas kernels vs the oracles."""

import jax.numpy as jnp
import numpy as np

OOB4 = np.zeros(4, np.int32)
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import lud, ref, srad


def rand(shape, seed=0, lo=0.0, hi=1.0):
    rs = np.random.RandomState(seed)
    return (lo + (hi - lo) * rs.rand(*shape)).astype(np.float32)


# ---------------------------------------------------------------------------
# SRAD
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(block=st.sampled_from([8, 20]), steps=st.integers(1, 2),
       seed=st.integers(0, 2**31 - 1))
def test_srad_tile_matches_ref(block, steps, seed):
    h = 2 * steps
    n = block + 2 * h
    # strictly positive image (SRAD divides by the image)
    img = rand((n, n), seed, 0.5, 2.0)
    q0s = rand((steps,), seed + 1, 0.05, 0.3)
    out = srad.srad_tile((n, n), model.SRAD_LAMBDA, steps)(img, q0s, OOB4)

    x = jnp.asarray(img)
    for t in range(steps):
        x = ref.srad_step(x, model.SRAD_LAMBDA, float(q0s[t]))
    want = np.asarray(x)[h:-h, h:-h]
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([16, 33]), seed=st.integers(0, 2**31 - 1))
def test_sum_sumsq_tile(n, seed):
    x = rand((n, n), seed)
    out = np.asarray(srad.sum_sumsq_tile((n, n))(x))
    np.testing.assert_allclose(out[0], x.sum(), rtol=1e-5)
    np.testing.assert_allclose(out[1], (x * x).sum(), rtol=1e-5)


def test_srad_full_iteration_via_partials():
    """q0sqr assembled from per-tile partial reductions matches the oracle."""
    n, bs = 32, 16
    img = rand((n, n), 5, 0.5, 2.0)
    red = srad.sum_sumsq_tile((bs, bs))
    total = np.zeros(2, dtype=np.float64)
    for i in range(0, n, bs):
        for j in range(0, n, bs):
            total += np.asarray(red(img[i:i + bs, j:j + bs]), dtype=np.float64)
    mean = total[0] / img.size
    var = total[1] / img.size - mean * mean
    q0 = var / (mean * mean)
    np.testing.assert_allclose(q0, float(ref.srad_q0sqr(jnp.asarray(img))),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# LUD
# ---------------------------------------------------------------------------

def diag_dominant(n, seed):
    a = rand((n, n), seed, -1.0, 1.0)
    a += n * np.eye(n, dtype=np.float32)
    return a


@settings(max_examples=10, deadline=None)
@given(b=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_lud_diagonal_tile(b, seed):
    a = diag_dominant(b, seed)
    out = np.asarray(lud.lud_diagonal_tile(b)(a))
    want = np.asarray(ref.lud_diagonal(jnp.asarray(a)))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
def test_lud_perimeter_row_tile(b, seed):
    diag = diag_dominant(b, seed)
    diag_lu = np.asarray(ref.lud_diagonal(jnp.asarray(diag)))
    a_row = rand((b, b), seed + 1, -1.0, 1.0)
    out = np.asarray(lud.lud_perimeter_row_tile(b)(diag_lu, a_row))
    want = np.asarray(ref.lud_perimeter_row(jnp.asarray(diag_lu), jnp.asarray(a_row)))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
def test_lud_perimeter_col_tile(b, seed):
    diag = diag_dominant(b, seed)
    diag_lu = np.asarray(ref.lud_diagonal(jnp.asarray(diag)))
    a_col = rand((b, b), seed + 2, -1.0, 1.0)
    out = np.asarray(lud.lud_perimeter_col_tile(b)(diag_lu, a_col))
    want = np.asarray(ref.lud_perimeter_col(jnp.asarray(diag_lu), jnp.asarray(a_col)))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lud_internal_tile(seed):
    b = 16
    c = rand((b, b), seed)
    a = rand((b, b), seed + 1)
    bb = rand((b, b), seed + 2)
    out = np.asarray(lud.lud_internal_tile(b)(c, a, bb))
    want = c - a @ bb
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


def test_lud_blocked_full_factorization():
    """Full blocked LUD (diag + perimeter + internal kernels composed the
    way the Rust coordinator composes them) reproduces the whole-matrix
    oracle — the Rodinia algorithm end to end."""
    b, nb = 8, 3
    n = b * nb
    a = diag_dominant(n, 9).astype(np.float32)
    m = a.copy()

    kd = lud.lud_diagonal_tile(b)
    kr = lud.lud_perimeter_row_tile(b)
    kc = lud.lud_perimeter_col_tile(b)
    ki = lud.lud_internal_tile(b)

    for k in range(nb):
        s = k * b
        m[s:s + b, s:s + b] = np.asarray(kd(m[s:s + b, s:s + b]))
        dlu = m[s:s + b, s:s + b]
        for j in range(k + 1, nb):
            cs = j * b
            m[s:s + b, cs:cs + b] = np.asarray(kr(dlu, m[s:s + b, cs:cs + b]))
            m[cs:cs + b, s:s + b] = np.asarray(kc(dlu, m[cs:cs + b, s:s + b]))
        for i in range(k + 1, nb):
            for j in range(k + 1, nb):
                rs_, cs = i * b, j * b
                m[rs_:rs_ + b, cs:cs + b] = np.asarray(
                    ki(m[rs_:rs_ + b, cs:cs + b],
                       m[rs_:rs_ + b, s:s + b],
                       m[s:s + b, cs:cs + b]))

    want = np.asarray(ref.lud(jnp.asarray(a)))
    np.testing.assert_allclose(m, want, rtol=1e-3, atol=1e-4)

    # and L @ U reconstructs A
    l = np.tril(m, -1) + np.eye(n, dtype=np.float32)
    u = np.triu(m)
    np.testing.assert_allclose(l @ u, a, rtol=1e-3, atol=1e-3)
