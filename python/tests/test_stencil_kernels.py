"""Pallas stencil kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, radii and fused-step counts; every property is
the same: running the tile kernel on a halo'd tile must equal running the
whole-array reference on that tile and cropping the interior.  (Within the
halo contract the boundary condition is irrelevant — interior cells never
read beyond the tile — so zero-boundary references are valid for both
conventions.)
"""

import jax.numpy as jnp
import numpy as np

OOB4 = np.zeros(4, np.int32)
OOB6 = np.zeros(6, np.int32)
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, stencil2d, stencil3d


def rand(shape, seed=0, lo=0.0, hi=1.0):
    rs = np.random.RandomState(seed)
    return (lo + (hi - lo) * rs.rand(*shape)).astype(np.float32)


@settings(max_examples=12, deadline=None)
@given(
    radius=st.integers(1, 4),
    steps=st.integers(1, 3),
    block=st.sampled_from([8, 17, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_diffusion2d_tile_matches_ref(radius, steps, block, seed):
    coeffs = model.star_coeffs(radius, 2)
    h = radius * steps
    tile = rand((block + 2 * h, block + 2 * h), seed)
    out = stencil2d.diffusion2d_tile(tile.shape, coeffs, steps)(tile, OOB4)
    want = ref.diffusion2d(jnp.asarray(tile), coeffs, steps)[h:-h, h:-h]
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    radius=st.integers(1, 3),
    steps=st.integers(1, 2),
    block=st.sampled_from([6, 9, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_diffusion3d_tile_matches_ref(radius, steps, block, seed):
    coeffs = model.star_coeffs(radius, 3)
    h = radius * steps
    n = block + 2 * h
    tile = rand((n, n, n), seed)
    out = stencil3d.diffusion3d_tile(tile.shape, coeffs, steps)(tile, OOB6)
    want = ref.diffusion3d(jnp.asarray(tile), coeffs, steps)[h:-h, h:-h, h:-h]
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(steps=st.integers(1, 4), block=st.sampled_from([8, 24]),
       seed=st.integers(0, 2**31 - 1))
def test_hotspot2d_tile_matches_ref(steps, block, seed):
    h = steps
    n = block + 2 * h
    temp = rand((n, n), seed, 60.0, 90.0)
    power = rand((n, n), seed + 1, 0.0, 1.0)
    out = stencil2d.hotspot2d_tile((n, n), model.HOTSPOT2D_PARAMS, steps)(temp, power, OOB4)
    want = ref.hotspot2d(
        jnp.asarray(temp), jnp.asarray(power),
        steps=steps, **model.HOTSPOT2D_PARAMS,
    )[h:-h, h:-h]
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(steps=st.integers(1, 2), block=st.sampled_from([6, 12]),
       seed=st.integers(0, 2**31 - 1))
def test_hotspot3d_tile_matches_ref(steps, block, seed):
    h = steps
    n = block + 2 * h
    temp = rand((n, n, n), seed, 60.0, 90.0)
    power = rand((n, n, n), seed + 1, 0.0, 1.0)
    out = stencil3d.hotspot3d_tile((n, n, n), model.HOTSPOT3D_PARAMS, steps)(temp, power, OOB6)
    want = ref.hotspot3d(
        jnp.asarray(temp), jnp.asarray(power),
        coeffs=model.HOTSPOT3D_PARAMS, steps=steps,
    )[h:-h, h:-h, h:-h]
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


def test_interior_independent_of_boundary_convention():
    """The halo contract: interior output never reads beyond the tile."""
    r, steps = 2, 2
    h = r * steps
    coeffs = model.star_coeffs(r, 2)
    tile = rand((16 + 2 * h, 16 + 2 * h), 7)
    k = stencil2d.diffusion2d_tile(tile.shape, coeffs, steps)
    out = np.asarray(k(tile, OOB4))
    # both zero- and clamp-boundary references agree on the interior
    want_zero = ref.diffusion2d(jnp.asarray(tile), coeffs, steps)[h:-h, h:-h]
    np.testing.assert_allclose(out, want_zero, rtol=1e-5, atol=1e-6)


def test_star_coeffs_stable():
    for ndim in (2, 3):
        for r in range(1, 5):
            c = model.star_coeffs(r, ndim)
            assert all(x > 0 for x in c)
            total = c[0] + 2 * ndim * sum(c[1:])
            assert abs(total - 1.0) < 1e-12
