"""AOT lowering: jax → HLO *text* artifacts for the Rust PJRT runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  Lowering goes jit → stablehlo → XlaComputation(return_tuple=True)
→ ``as_hlo_text()``; the Rust side unwraps the 1-tuple (or n-tuple).

Usage::

    python -m compile.aot --out-dir ../artifacts [--only name[,name...]]

Also writes ``manifest.txt``: one line per artifact,
``name|file|in=<sig>;...|out=<sig>;...|meta k=v;...`` — the Rust artifact
registry (rust/src/runtime/registry.rs) parses this to know operand shapes
and the static parameters baked into each compilation unit.
"""

from __future__ import annotations

import argparse
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS, artifacts


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals) -> str:
    parts = []
    for a in avals:
        dt = np.dtype(a.dtype).name
        parts.append(f"{dt}[{','.join(str(d) for d in a.shape)}]")
    return ";".join(parts)


def lower_artifact(art) -> tuple:
    """Returns (hlo_text, out_signature) for one artifact."""
    fn = art.build()
    lowered = jax.jit(fn).lower(*art.inputs)
    out_aval = lowered.out_info
    # out_info is a pytree of ShapeDtypeStruct; flatten it
    leaves = jax.tree_util.tree_leaves(out_aval)
    return to_hlo_text(lowered), _sig(leaves)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names to (re)build")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    manifest_path = os.path.join(args.out_dir, "manifest.txt")

    lines = []
    for art in artifacts():
        if only is not None and art.name not in only:
            continue
        fname = f"{art.name}.hlo.txt"
        hlo, out_sig = lower_artifact(art)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(hlo)
        meta = ";".join(f"{k}={v}" for k, v in sorted(art.meta.items()))
        lines.append(f"{art.name}|{fname}|in={_sig(art.inputs)}|out={out_sig}|meta {meta}")
        print(f"  lowered {art.name}: {len(hlo)} chars -> {fname}")

    if only is None:
        with open(manifest_path, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"wrote manifest with {len(lines)} artifacts to {manifest_path}")
    else:
        print("(partial build: manifest not rewritten)")


if __name__ == "__main__":
    main()
