"""L2: the JAX compute-graph layer — artifact definitions for AOT lowering.

Each entry in :data:`ARTIFACTS` names one compiled compute unit the Rust
coordinator loads at run time (the analogue of one synthesized FPGA kernel
variant in the thesis).  An artifact is a jit-able callable built from the
L1 pallas kernels plus the static parameters baked into it — block size,
stencil radius, fused time steps, coefficients — mirroring how the thesis
bakes ``BSIZE``/``PAR``/``RAD``/``TIME`` into each bitstream (§5.3).

All run-time-variable data (grid contents, reduction scalars) enters as
operands; everything else is compile-time constant, keeping Python strictly
on the build path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import dynprog, lud, srad, stencil2d, stencil3d


# ---------------------------------------------------------------------------
# Shared static parameters (mirrored into the artifact manifest so the Rust
# coordinator and reference implementations use identical constants)
# ---------------------------------------------------------------------------

def star_coeffs(radius: int, ndim: int) -> tuple:
    """Stable star-stencil coefficients ``[c0, c1..cr]`` for any order.

    ``c_d = alpha / d²`` with the centre weight chosen so all coefficients
    are positive and sum to 1 (diffusion-stable: spectral radius ≤ 1).
    """
    alpha = 0.06
    neigh = 2 * ndim
    cds = [alpha / (d * d) for d in range(1, radius + 1)]
    c0 = 1.0 - neigh * sum(cds)
    assert c0 > 0.0
    return tuple([c0] + cds)


HOTSPOT2D_PARAMS = {"cap": 0.05, "rx": 1.0, "ry": 1.0, "rz": 4.0, "amb": 80.0}

HOTSPOT3D_PARAMS = {
    "cc": 0.68, "cn": 0.06, "cs": 0.06, "ce": 0.06, "cw": 0.06,
    "ct": 0.04, "cb": 0.04, "sdc": 0.01, "amb": 80.0,
}

SRAD_LAMBDA = 0.5
NW_PENALTY = 10

# Default block geometry per artifact family.  2D tiles keep the last dim a
# multiple of 128 (VPU lanes); 3D tiles trade z-depth for plane size the way
# the thesis's 3.5D blocking trades block height for width.
BLOCK_2D = 256
BLOCK_3D = 32
PATHFINDER_WIDTH = 4096
PATHFINDER_FUSED = 8
NW_BLOCK = 64
LUD_BLOCK = 64


@dataclass
class Artifact:
    """One AOT compilation unit: callable + example operands + metadata."""

    name: str
    build: Callable[[], Callable]
    inputs: list
    meta: dict = field(default_factory=dict)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# ---------------------------------------------------------------------------
# Stencil artifacts (Ch. 5): diffusion 2D/3D for radius 1..4 + Rodinia
# hotspot 2D/3D.  Fused steps per radius follow the thesis's tuned configs
# (Table 5-6/5-7: deeper temporal blocking for cheaper stencils).
# ---------------------------------------------------------------------------

DIFF2D_STEPS = {1: 4, 2: 2, 3: 2, 4: 1}
DIFF3D_STEPS = {1: 2, 2: 1, 3: 1, 4: 1}


def _diffusion2d(radius: int) -> Artifact:
    steps = DIFF2D_STEPS[radius]
    h = radius * steps
    tile = (BLOCK_2D + 2 * h, BLOCK_2D + 2 * h)
    coeffs = star_coeffs(radius, 2)
    return Artifact(
        name=f"diffusion2d_r{radius}",
        build=lambda: stencil2d.diffusion2d_tile(tile, coeffs, steps),
        inputs=[_f32(*tile), _i32(4)],
        meta={
            "kind": "stencil2d", "radius": radius, "steps": steps,
            "block": BLOCK_2D, "halo": h,
            "coeffs": ",".join(f"{c:.9g}" for c in coeffs),
            "boundary": "zero",
        },
    )


def _diffusion3d(radius: int) -> Artifact:
    steps = DIFF3D_STEPS[radius]
    h = radius * steps
    tile = (BLOCK_3D + 2 * h,) * 3
    coeffs = star_coeffs(radius, 3)
    return Artifact(
        name=f"diffusion3d_r{radius}",
        build=lambda: stencil3d.diffusion3d_tile(tile, coeffs, steps),
        inputs=[_f32(*tile), _i32(6)],
        meta={
            "kind": "stencil3d", "radius": radius, "steps": steps,
            "block": BLOCK_3D, "halo": h,
            "coeffs": ",".join(f"{c:.9g}" for c in coeffs),
            "boundary": "zero",
        },
    )


def _hotspot2d() -> Artifact:
    steps = 4
    h = steps
    tile = (BLOCK_2D + 2 * h, BLOCK_2D + 2 * h)
    return Artifact(
        name="hotspot2d",
        build=lambda: stencil2d.hotspot2d_tile(tile, HOTSPOT2D_PARAMS, steps),
        inputs=[_f32(*tile), _f32(*tile), _i32(4)],
        meta={
            "kind": "stencil2d", "radius": 1, "steps": steps,
            "block": BLOCK_2D, "halo": h, "boundary": "clamp",
            **{f"p_{k}": v for k, v in HOTSPOT2D_PARAMS.items()},
        },
    )


def _hotspot3d() -> Artifact:
    steps = 2
    h = steps
    tile = (BLOCK_3D + 2 * h,) * 3
    return Artifact(
        name="hotspot3d",
        build=lambda: stencil3d.hotspot3d_tile(tile, HOTSPOT3D_PARAMS, steps),
        inputs=[_f32(*tile), _f32(*tile), _i32(6)],
        meta={
            "kind": "stencil3d", "radius": 1, "steps": steps,
            "block": BLOCK_3D, "halo": h, "boundary": "clamp",
            **{f"p_{k}": v for k, v in HOTSPOT3D_PARAMS.items()},
        },
    )


# ---------------------------------------------------------------------------
# Dynamic programming artifacts (Ch. 4)
# ---------------------------------------------------------------------------

def _pathfinder() -> Artifact:
    w, t = PATHFINDER_WIDTH, PATHFINDER_FUSED
    return Artifact(
        name="pathfinder",
        build=lambda: dynprog.pathfinder_tile(w, t),
        inputs=[_i32(w + 2 * t), _i32(t, w + 2 * t)],
        meta={"kind": "dynprog", "width": w, "fused_rows": t,
              "boundary": "clamp"},
    )


def _nw() -> Artifact:
    b = NW_BLOCK
    return Artifact(
        name="nw",
        build=lambda: dynprog.nw_tile(b, b, NW_PENALTY),
        inputs=[_i32(b), _i32(b), _i32(1), _i32(b, b)],
        meta={"kind": "dynprog", "block": b, "penalty": NW_PENALTY},
    )


# ---------------------------------------------------------------------------
# SRAD artifacts (Ch. 4): fused reduction + fused two-pass stencil
# ---------------------------------------------------------------------------

def _srad() -> Artifact:
    steps = 1
    h = 2 * steps
    tile = (BLOCK_2D + 2 * h, BLOCK_2D + 2 * h)
    return Artifact(
        name="srad",
        build=lambda: srad.srad_tile(tile, SRAD_LAMBDA, steps),
        inputs=[_f32(*tile), _f32(steps), _i32(4)],
        meta={"kind": "stencil2d", "radius": 2, "steps": steps,
              "block": BLOCK_2D, "halo": h, "lambda": SRAD_LAMBDA,
              "boundary": "clamp"},
    )


def _sum_sumsq() -> Artifact:
    tile = (BLOCK_2D, BLOCK_2D)
    return Artifact(
        name="sum_sumsq",
        build=lambda: srad.sum_sumsq_tile(tile),
        inputs=[_f32(*tile)],
        meta={"kind": "reduction", "block": BLOCK_2D},
    )


# ---------------------------------------------------------------------------
# LUD artifacts (Ch. 4): the three Rodinia kernels
# ---------------------------------------------------------------------------

def _lud_internal() -> Artifact:
    b = LUD_BLOCK
    return Artifact(
        name="lud_internal",
        build=lambda: lud.lud_internal_tile(b),
        inputs=[_f32(b, b), _f32(b, b), _f32(b, b)],
        meta={"kind": "lud", "block": b},
    )


def _lud_diagonal() -> Artifact:
    b = LUD_BLOCK
    return Artifact(
        name="lud_diagonal",
        build=lambda: lud.lud_diagonal_tile(b),
        inputs=[_f32(b, b)],
        meta={"kind": "lud", "block": b},
    )


def _lud_perimeter_row() -> Artifact:
    b = LUD_BLOCK
    return Artifact(
        name="lud_perimeter_row",
        build=lambda: lud.lud_perimeter_row_tile(b),
        inputs=[_f32(b, b), _f32(b, b)],
        meta={"kind": "lud", "block": b},
    )


def _lud_perimeter_col() -> Artifact:
    b = LUD_BLOCK
    return Artifact(
        name="lud_perimeter_col",
        build=lambda: lud.lud_perimeter_col_tile(b),
        inputs=[_f32(b, b), _f32(b, b)],
        meta={"kind": "lud", "block": b},
    )


def artifacts() -> list:
    """The full artifact set, in manifest order."""
    out = []
    for r in (1, 2, 3, 4):
        out.append(_diffusion2d(r))
    for r in (1, 2, 3, 4):
        out.append(_diffusion3d(r))
    out.append(_hotspot2d())
    out.append(_hotspot3d())
    out.append(_pathfinder())
    out.append(_nw())
    out.append(_srad())
    out.append(_sum_sumsq())
    out.append(_lud_internal())
    out.append(_lud_diagonal())
    out.append(_lud_perimeter_row())
    out.append(_lud_perimeter_col())
    return out


ARTIFACTS = {a.name: a for a in artifacts()}
