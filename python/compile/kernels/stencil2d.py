"""L1 Pallas kernels: 2D star-shaped stencils with fused temporal blocking.

This is the TPU-side re-thinking of the thesis's FPGA stencil accelerator
(DESIGN.md §Hardware-Adaptation):

* The FPGA's *shift register* (one stencil window resident on-chip, streamed
  over the grid) becomes a **VMEM-resident tile**: the kernel receives one
  spatial block *plus its halo* and keeps it entirely in VMEM.
* The FPGA's *temporal blocking* (chained compute units, one per fused time
  step, §5.3.2) becomes an **in-kernel fused time loop**: ``steps``
  applications of the stencil run back-to-back on the VMEM tile before a
  single write-back, trading redundant halo compute for external-memory
  traffic exactly like the thesis does.
* The FPGA's ``par``-wide vectorization becomes VPU lanes: callers should
  keep the last tile dimension a multiple of 128.

Halo contract (shared with rust/src/coordinator/grid.rs): for radius ``r``
and ``steps`` fused time steps the input tile carries ``h = r*steps`` halo
cells per side; the output is the interior, ``tile[h:-h, h:-h]``.  The
in-kernel neighbourhood access uses ``jnp.roll``; the wrap-around garbage a
roll introduces travels at most ``r`` cells inward per step, i.e. it is
always confined to the halo ring that the next step consumes — the interior
written back is exact.

Physical-boundary contract: halo cells that fall *outside the grid* cannot
be left to evolve like ordinary cells — the boundary condition must be
re-imposed after **every fused step**, not once per pass (the same reason
the thesis's kernels carry global-index boundary checks, §5.3.3).  Each
kernel therefore takes an ``oob`` operand ``[top, bottom, left, right]``
(i32 counts of out-of-grid cells per side of this tile) and restores the
boundary in-kernel each step: Dirichlet tiles multiply by the in-grid mask,
clamp tiles gather edge rows/columns outward.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def zero_mask2d(shape, oob):
    """In-grid mask (1.0 inside, 0.0 outside) from the oob descriptor."""
    ny, nx = shape
    yi = lax.broadcasted_iota(jnp.int32, shape, 0)
    xi = lax.broadcasted_iota(jnp.int32, shape, 1)
    ok = (yi >= oob[0]) & (yi < ny - oob[1]) & (xi >= oob[2]) & (xi < nx - oob[3])
    return ok.astype(jnp.float32)


def clamp_restore2d(x, oob):
    """Re-impose clamp boundary: out-of-grid cells copy the nearest
    in-grid cell (rows first, then columns — corners resolve exactly)."""
    ny, nx = x.shape
    yi = jnp.clip(lax.iota(jnp.int32, ny), oob[0], ny - 1 - oob[1])
    x = jnp.take(x, yi, axis=0)
    xi = jnp.clip(lax.iota(jnp.int32, nx), oob[2], nx - 1 - oob[3])
    return jnp.take(x, xi, axis=1)


def shift2d(x: jnp.ndarray, off: int, axis: int) -> jnp.ndarray:
    """Zero-fill shift via pad+slice.

    Perf note (EXPERIMENTS.md §Perf L1): XLA CPU fuses pad+slice chains
    ~9x better than jnp.roll (roll lowers to concatenate pairs that defeat
    loop fusion).  Zero fill at the tile edge is as sacrificial as roll
    wrap: the corruption ring grows r per step and stays inside the halo.
    """
    if off == 0:
        return x
    pad = [(0, 0), (0, 0)]
    sl = [slice(None), slice(None)]
    n = x.shape[axis]
    if off > 0:
        pad[axis] = (off, 0)
        sl[axis] = slice(0, n)
    else:
        pad[axis] = (0, -off)
        sl[axis] = slice(-off, n - off)
    return jnp.pad(x, pad)[tuple(sl)]


def _star2d(x: jnp.ndarray, coeffs) -> jnp.ndarray:
    """One star-shaped update on the full tile (garbage in halo only)."""
    out = coeffs[0] * x
    for d in range(1, len(coeffs)):
        out = out + coeffs[d] * (
            shift2d(x, d, 0)
            + shift2d(x, -d, 0)
            + shift2d(x, d, 1)
            + shift2d(x, -d, 1)
        )
    return out


def diffusion2d_tile(tile_shape, coeffs, steps: int):
    """Build the fused-time-step diffusion kernel for one VMEM tile.

    Args:
      tile_shape: (ny, nx) of the *input* tile including halos.
      coeffs: ``[c0, c1, ..., cr]`` star coefficients (static, baked into
        the artifact like the FPGA design's compile-time constants).
      steps: number of fused time steps (the thesis's degree of temporal
        parallelism).

    Returns a jit-able ``f(tile) -> interior`` where interior has shape
    ``(ny - 2*r*steps, nx - 2*r*steps)``.
    """
    r = len(coeffs) - 1
    h = r * steps
    ny, nx = tile_shape
    assert ny > 2 * h and nx > 2 * h, "tile must be larger than its halo"
    out_shape = (ny - 2 * h, nx - 2 * h)
    coeffs = tuple(float(c) for c in coeffs)

    def kernel(x_ref, oob_ref, o_ref):
        x = x_ref[...]
        oob = oob_ref[...]
        mask = zero_mask2d((ny, nx), oob)
        for _ in range(steps):
            x = _star2d(x, coeffs) * mask
        o_ref[...] = x[h:ny - h, h:nx - h]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=True,
    )


def hotspot2d_tile(tile_shape, params, steps: int):
    """Fused-time-step Rodinia Hotspot kernel for one VMEM tile.

    ``params`` is a dict with keys cap/rx/ry/rz/amb (static).  Takes the
    temperature tile *and* the co-located power tile (same shape — power is
    only consumed at the centre cell but fused steps need its halo too).
    """
    cap = float(params["cap"])
    rx = float(params["rx"])
    ry = float(params["ry"])
    rz = float(params["rz"])
    amb = float(params["amb"])
    ny, nx = tile_shape
    h = steps  # radius 1
    assert ny > 2 * h and nx > 2 * h
    out_shape = (ny - 2 * h, nx - 2 * h)

    def step(t: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
        n = shift2d(t, 1, 0)
        s = shift2d(t, -1, 0)
        w = shift2d(t, 1, 1)
        e = shift2d(t, -1, 1)
        delta = cap * (
            p
            + (n + s - 2.0 * t) / ry
            + (e + w - 2.0 * t) / rx
            + (amb - t) / rz
        )
        return t + delta

    def kernel(t_ref, p_ref, oob_ref, o_ref):
        t = t_ref[...]
        p = p_ref[...]
        oob = oob_ref[...]
        for _ in range(steps):
            t = clamp_restore2d(step(t, p), oob)
        o_ref[...] = t[h:ny - h, h:nx - h]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=True,
    )


@functools.lru_cache(maxsize=None)
def _jitted_diffusion2d(tile_shape, coeffs, steps):
    return jax.jit(diffusion2d_tile(tile_shape, coeffs, steps))


def run_diffusion2d_tile(tile, coeffs, steps, oob=(0, 0, 0, 0)):
    """Convenience entry used by the pytest suite."""
    import numpy as np
    return _jitted_diffusion2d(tile.shape, tuple(float(c) for c in coeffs), steps)(
        tile, np.asarray(oob, np.int32))
