"""L1 Pallas kernels for the FPGA-HPC reproduction.

Each module provides *kernel builders*: functions taking static parameters
(tile shape, coefficients, fused-step counts — the analogue of the FPGA
design's compile-time constants) and returning a pallas_call-wrapped
callable.  ``ref`` holds the pure-jnp oracles every kernel is tested
against.
"""

from . import dynprog, lud, ref, srad, stencil2d, stencil3d  # noqa: F401
