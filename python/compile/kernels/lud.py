"""L1 Pallas kernels for blocked LU decomposition (Rodinia LUD).

The thesis's LUD (§4.3.1.6) keeps Rodinia's three-kernel structure —
*diameter* (diagonal block LU), *perimeter* (block row/column triangular
solves) and *internal* (Schur-complement GEMM) — and spends nearly all its
run time in *internal*.  The TPU adaptation:

* ``lud_internal_tile`` — the GEMM hot spot, an MXU-shaped
  ``C - A @ B`` over (b, b) f32 tiles (bake b as a multiple of 128 for real
  MXU efficiency; correctness runs use smaller interpreted tiles).
* ``lud_diagonal_tile`` / perimeter solves — small sequential factorisations
  expressed as masked rank-1 update loops (``fori_loop`` + iota masks), the
  vector analogue of the thesis's shift-register reduction pipelines.

All kernels use the combined L\\U in-place layout Rodinia uses (unit lower
diagonal implied).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def lud_internal_tile(b: int):
    """Schur-complement update for one internal block: ``C - A @ B``."""

    def kernel(c_ref, a_ref, b_ref, o_ref):
        o_ref[...] = c_ref[...] - jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )


def lud_diagonal_tile(b: int):
    """In-place LU of one (b, b) diagonal block, combined L\\U output."""
    def kernel(a_ref, o_ref):
        rows = lax.broadcasted_iota(jnp.int32, (b, b), 0)
        cols = lax.broadcasted_iota(jnp.int32, (b, b), 1)
        a = a_ref[...]

        def step(k, a):
            pivot = a[k, k]
            # scale column k below the diagonal
            colmask = (cols == k) & (rows > k)
            a = jnp.where(colmask, a / pivot, a)
            # rank-1 trailing update
            lk = jnp.where(rows > k, a, 0.0)[:, k][:, None]      # L[:, k] masked i>k
            uk = jnp.where(cols > k, a, 0.0)[k, :][None, :]      # U[k, :] masked j>k
            upd = lk * uk
            trail = (rows > k) & (cols > k)
            return jnp.where(trail, a - upd, a)

        o_ref[...] = lax.fori_loop(0, b, step, a)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )


def lud_perimeter_row_tile(b: int):
    """Forward solve ``L_diag · X = A_row`` (unit lower L from diag LU)."""
    def kernel(lu_ref, a_ref, o_ref):
        rows = lax.broadcasted_iota(jnp.int32, (b, b), 0)
        lu = lu_ref[...]

        def step(k, x):
            # x[i, :] -= L[i, k] * x[k, :]  for all i > k
            lk = jnp.where(rows > k, lu, 0.0)[:, k][:, None]
            return x - lk * x[k, :][None, :]

        o_ref[...] = lax.fori_loop(0, b, step, a_ref[...])

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )


def lud_perimeter_col_tile(b: int):
    """Back-substitute ``X · U_diag = A_col`` (upper U from diag LU)."""
    def kernel(lu_ref, a_ref, o_ref):
        rows = lax.broadcasted_iota(jnp.int32, (b, b), 0)
        cols = lax.broadcasted_iota(jnp.int32, (b, b), 1)
        lu = lu_ref[...]
        u = jnp.where(rows <= cols, lu, 0.0)
        a = a_ref[...]

        def step(j, x):
            # x[:, j] = (a[:, j] - X[:, :j] @ U[:j, j]) / U[j, j]
            kidx = lax.iota(jnp.int32, b)
            uc = jnp.where(kidx < j, u[:, j], 0.0)
            solved = (a[:, j] - x @ uc) / u[j, j]
            mask = cols == j
            return jnp.where(mask, solved[:, None], x)

        x0 = jnp.zeros((b, b), dtype=jnp.float32)
        o_ref[...] = lax.fori_loop(0, b, step, x0)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )
