"""L1 Pallas kernels: 3D star-shaped stencils with fused temporal blocking.

3D analogue of :mod:`.stencil2d`, mirroring the thesis's 3.5D-blocking
accelerator (§5.3): two blocked spatial dimensions live in the VMEM tile,
the z walk is driven by the Rust coordinator (the FPGA "streamed" dimension
maps to the coordinator's block schedule, since a CPU/TPU tile holds a 3D
sub-volume rather than a rolling plane window).

Halo contract: input tile is (nz, ny, nx) with ``h = r*steps`` halo on every
face; output is the interior ``tile[h:-h, h:-h, h:-h]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def zero_mask3d(shape, oob):
    """In-grid mask from the oob descriptor [z0, z1, y0, y1, x0, x1]."""
    nz, ny, nx = shape
    zi = lax.broadcasted_iota(jnp.int32, shape, 0)
    yi = lax.broadcasted_iota(jnp.int32, shape, 1)
    xi = lax.broadcasted_iota(jnp.int32, shape, 2)
    ok = (
        (zi >= oob[0]) & (zi < nz - oob[1])
        & (yi >= oob[2]) & (yi < ny - oob[3])
        & (xi >= oob[4]) & (xi < nx - oob[5])
    )
    return ok.astype(jnp.float32)


def clamp_restore3d(x, oob):
    """Re-impose clamp boundary axis by axis (see stencil2d)."""
    nz, ny, nx = x.shape
    zi = jnp.clip(lax.iota(jnp.int32, nz), oob[0], nz - 1 - oob[1])
    x = jnp.take(x, zi, axis=0)
    yi = jnp.clip(lax.iota(jnp.int32, ny), oob[2], ny - 1 - oob[3])
    x = jnp.take(x, yi, axis=1)
    xi = jnp.clip(lax.iota(jnp.int32, nx), oob[4], nx - 1 - oob[5])
    return jnp.take(x, xi, axis=2)


def shift3d(x: jnp.ndarray, off: int, axis: int) -> jnp.ndarray:
    """Zero-fill shift via pad+slice (see stencil2d.shift2d perf note)."""
    if off == 0:
        return x
    pad = [(0, 0)] * 3
    sl = [slice(None)] * 3
    n = x.shape[axis]
    if off > 0:
        pad[axis] = (off, 0)
        sl[axis] = slice(0, n)
    else:
        pad[axis] = (0, -off)
        sl[axis] = slice(-off, n - off)
    return jnp.pad(x, pad)[tuple(sl)]


def _star3d(x: jnp.ndarray, coeffs) -> jnp.ndarray:
    out = coeffs[0] * x
    for d in range(1, len(coeffs)):
        acc = None
        for axis in range(3):
            term = shift3d(x, d, axis) + shift3d(x, -d, axis)
            acc = term if acc is None else acc + term
        out = out + coeffs[d] * acc
    return out


def diffusion3d_tile(tile_shape, coeffs, steps: int):
    """Fused-time-step 3D diffusion kernel for one VMEM tile."""
    r = len(coeffs) - 1
    h = r * steps
    nz, ny, nx = tile_shape
    assert min(nz, ny, nx) > 2 * h, "tile must be larger than its halo"
    out_shape = (nz - 2 * h, ny - 2 * h, nx - 2 * h)
    coeffs = tuple(float(c) for c in coeffs)

    def kernel(x_ref, oob_ref, o_ref):
        x = x_ref[...]
        oob = oob_ref[...]
        mask = zero_mask3d((nz, ny, nx), oob)
        for _ in range(steps):
            x = _star3d(x, coeffs) * mask
        o_ref[...] = x[h:nz - h, h:ny - h, h:nx - h]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=True,
    )


def hotspot3d_tile(tile_shape, params, steps: int):
    """Fused-time-step Rodinia Hotspot3D kernel (7-point + power + ambient).

    ``params``: dict with cc/cn/cs/ce/cw/ct/cb/sdc/amb, all static floats.
    Axis layout (z, y, x); the ambient term rides on the ``ct`` coefficient
    exactly as in Rodinia's kernel.
    """
    cc = float(params["cc"])
    cn = float(params["cn"])
    cs = float(params["cs"])
    ce = float(params["ce"])
    cw = float(params["cw"])
    ct = float(params["ct"])
    cb = float(params["cb"])
    sdc = float(params["sdc"])
    amb = float(params["amb"])
    nz, ny, nx = tile_shape
    h = steps  # radius 1
    assert min(nz, ny, nx) > 2 * h
    out_shape = (nz - 2 * h, ny - 2 * h, nx - 2 * h)

    def step(t: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
        n = shift3d(t, 1, 1)
        s = shift3d(t, -1, 1)
        w = shift3d(t, 1, 2)
        e = shift3d(t, -1, 2)
        top = shift3d(t, 1, 0)
        bot = shift3d(t, -1, 0)
        return (
            cc * t + cn * n + cs * s + ce * e + cw * w + ct * top + cb * bot
            + sdc * p + ct * amb
        )

    def kernel(t_ref, p_ref, oob_ref, o_ref):
        t = t_ref[...]
        p = p_ref[...]
        oob = oob_ref[...]
        for _ in range(steps):
            t = clamp_restore3d(step(t, p), oob)
        o_ref[...] = t[h:nz - h, h:ny - h, h:nx - h]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=True,
    )
