"""Pure-jnp reference oracles for every kernel in the library.

These are the *correctness ground truth*: deliberately simple, written with
whole-array jnp ops (no pallas, no blocking, no fused time steps).  Every
pallas kernel and every composed L2 model is pytest-verified against the
functions in this module, and the Rust coordinator's streamed execution is
verified end-to-end against HLO lowered straight from these references.

Boundary conventions (shared with the Rust coordinator, see
rust/src/coordinator/grid.rs):

* ``diffusion`` (Ch. 5 benchmarks): Dirichlet zero — cells outside the grid
  read as 0.0.
* ``hotspot`` / ``srad`` / ``pathfinder`` (Rodinia): clamp — out-of-bound
  neighbours fall back to the nearest border cell, matching Rodinia's
  original kernels.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Shifting helpers
# ---------------------------------------------------------------------------

def shift_zero(x: jnp.ndarray, offset: int, axis: int) -> jnp.ndarray:
    """Shift ``x`` by ``offset`` along ``axis`` bringing zeros in.

    ``offset=+1`` moves values towards higher indices, i.e. the returned
    array at position i holds ``x[i - 1]`` — the *north/west* neighbour.
    """
    if offset == 0:
        return x
    pad = [(0, 0)] * x.ndim
    sl = [slice(None)] * x.ndim
    if offset > 0:
        pad[axis] = (offset, 0)
        sl[axis] = slice(0, x.shape[axis])
    else:
        pad[axis] = (0, -offset)
        sl[axis] = slice(-offset, x.shape[axis] - offset)
    return jnp.pad(x, pad)[tuple(sl)]


def shift_clamp(x: jnp.ndarray, offset: int, axis: int) -> jnp.ndarray:
    """Shift with edge-clamp semantics (Rodinia-style boundary)."""
    if offset == 0:
        return x
    pad = [(0, 0)] * x.ndim
    sl = [slice(None)] * x.ndim
    pad[axis] = (max(offset, 0), max(-offset, 0))
    if offset > 0:
        sl[axis] = slice(0, x.shape[axis])
    else:
        sl[axis] = slice(-offset, x.shape[axis] - offset)
    return jnp.pad(x, pad, mode="edge")[tuple(sl)]


# ---------------------------------------------------------------------------
# Star-shaped diffusion stencils (Ch. 5)
# ---------------------------------------------------------------------------

def diffusion2d_step(x: jnp.ndarray, coeffs) -> jnp.ndarray:
    """One first-to-fourth order star-shaped 2D diffusion step.

    ``coeffs`` has layout ``[c_center, c_1, c_2, ..., c_r]`` where ``c_d``
    multiplies all four neighbours at distance ``d`` (symmetric star, the
    form used by the thesis's high-order diffusion benchmark, §5.5.1).
    Out-of-grid cells read 0 (Dirichlet).
    """
    radius = len(coeffs) - 1
    out = coeffs[0] * x
    for d in range(1, radius + 1):
        out = out + coeffs[d] * (
            shift_zero(x, d, 0)
            + shift_zero(x, -d, 0)
            + shift_zero(x, d, 1)
            + shift_zero(x, -d, 1)
        )
    return out


def diffusion3d_step(x: jnp.ndarray, coeffs) -> jnp.ndarray:
    """One star-shaped 3D diffusion step; layout as :func:`diffusion2d_step`."""
    radius = len(coeffs) - 1
    out = coeffs[0] * x
    for d in range(1, radius + 1):
        acc = jnp.zeros_like(x)
        for axis in range(3):
            acc = acc + shift_zero(x, d, axis) + shift_zero(x, -d, axis)
        out = out + coeffs[d] * acc
    return out


def diffusion2d(x: jnp.ndarray, coeffs, steps: int) -> jnp.ndarray:
    for _ in range(steps):
        x = diffusion2d_step(x, coeffs)
    return x


def diffusion3d(x: jnp.ndarray, coeffs, steps: int) -> jnp.ndarray:
    for _ in range(steps):
        x = diffusion3d_step(x, coeffs)
    return x


# ---------------------------------------------------------------------------
# Hotspot / Hotspot 3D (Rodinia structured grid)
# ---------------------------------------------------------------------------

def hotspot2d_step(
    temp: jnp.ndarray,
    power: jnp.ndarray,
    *,
    cap: float,
    rx: float,
    ry: float,
    rz: float,
    amb: float,
) -> jnp.ndarray:
    """One Rodinia Hotspot step: 5-point stencil + power + ambient terms.

    ``delta = cap * (power + (N + S - 2T)/ry + (E + W - 2T)/rx + (amb - T)/rz)``
    with clamp boundaries, then ``out = T + delta``.
    """
    n = shift_clamp(temp, 1, 0)
    s = shift_clamp(temp, -1, 0)
    w = shift_clamp(temp, 1, 1)
    e = shift_clamp(temp, -1, 1)
    delta = cap * (
        power
        + (n + s - 2.0 * temp) / ry
        + (e + w - 2.0 * temp) / rx
        + (amb - temp) / rz
    )
    return temp + delta


def hotspot2d(temp, power, *, cap, rx, ry, rz, amb, steps: int):
    for _ in range(steps):
        temp = hotspot2d_step(temp, power, cap=cap, rx=rx, ry=ry, rz=rz, amb=amb)
    return temp


def hotspot3d_step(
    temp: jnp.ndarray,
    power: jnp.ndarray,
    *,
    cc: float,
    cn: float,
    cs: float,
    ce: float,
    cw: float,
    ct: float,
    cb: float,
    sdc: float,
    amb: float,
) -> jnp.ndarray:
    """One Rodinia Hotspot3D step (7-point stencil, clamp boundary).

    ``out = cc*T + cn*N + cs*S + ce*E + cw*W + ct*Top + cb*Bottom
    + sdc*power + ct*amb`` — the Rodinia formulation with all material
    constants folded into per-direction coefficients.  Axis layout is
    (z, y, x).
    """
    n = shift_clamp(temp, 1, 1)
    s = shift_clamp(temp, -1, 1)
    w = shift_clamp(temp, 1, 2)
    e = shift_clamp(temp, -1, 2)
    t = shift_clamp(temp, 1, 0)
    b = shift_clamp(temp, -1, 0)
    return (
        cc * temp + cn * n + cs * s + ce * e + cw * w + ct * t + cb * b
        + sdc * power + ct * amb
    )


def hotspot3d(temp, power, *, coeffs, steps: int):
    for _ in range(steps):
        temp = hotspot3d_step(temp, power, **coeffs)
    return temp


# ---------------------------------------------------------------------------
# Pathfinder (Rodinia dynamic programming)
# ---------------------------------------------------------------------------

def pathfinder_row(prev: jnp.ndarray, wall_row: jnp.ndarray) -> jnp.ndarray:
    """One Pathfinder row update: ``out[j] = wall[j] + min(prev[j-1..j+1])``."""
    left = shift_clamp(prev, 1, 0)
    right = shift_clamp(prev, -1, 0)
    return wall_row + jnp.minimum(jnp.minimum(left, prev), right)


def pathfinder(wall: jnp.ndarray) -> jnp.ndarray:
    """Full Pathfinder: accumulate from row 0 down, returns final cost row."""
    acc = wall[0]
    for i in range(1, wall.shape[0]):
        acc = pathfinder_row(acc, wall[i])
    return acc


# ---------------------------------------------------------------------------
# Needleman-Wunsch (Rodinia dynamic programming)
# ---------------------------------------------------------------------------

def nw(reference: jnp.ndarray, penalty: int) -> jnp.ndarray:
    """Needleman-Wunsch score matrix, sequential reference.

    ``reference`` is the (n, m) substitution-score matrix; entry (i, j)
    scores aligning sequence items i and j.  Row 0 / column 0 are the
    standard gap initialisation ``-i*penalty`` / ``-j*penalty``.  Returns
    the full (n, m) score matrix including the initialised borders.
    """
    n, m = reference.shape
    ref_np = np.asarray(reference)
    score = np.zeros((n, m), dtype=np.int32)
    score[0, :] = -penalty * np.arange(m, dtype=np.int32)
    score[:, 0] = -penalty * np.arange(n, dtype=np.int32)
    for i in range(1, n):
        for j in range(1, m):
            score[i, j] = max(
                score[i - 1, j - 1] + int(ref_np[i, j]),
                score[i - 1, j] - penalty,
                score[i, j - 1] - penalty,
            )
    return jnp.asarray(score)


# ---------------------------------------------------------------------------
# SRAD (Rodinia structured grid, two stencil passes + reduction)
# ---------------------------------------------------------------------------

def srad_step(img: jnp.ndarray, lam: float, q0sqr) -> jnp.ndarray:
    """One SRAD iteration (both passes) with clamp boundaries.

    Pass 1 computes the diffusion coefficient ``c`` per cell from the image
    gradient; pass 2 applies the divergence update using ``c`` of the south
    and east neighbours (Rodinia's formulation).
    """
    n = shift_clamp(img, 1, 0) - img    # north neighbour difference
    s = shift_clamp(img, -1, 0) - img   # south
    w = shift_clamp(img, 1, 1) - img    # west
    e = shift_clamp(img, -1, 1) - img   # east

    g2 = (n * n + s * s + w * w + e * e) / (img * img)
    l_ = (n + s + w + e) / img
    num = 0.5 * g2 - 0.0625 * (l_ * l_)
    den = 1.0 + 0.25 * l_
    qsqr = num / (den * den)

    den2 = (qsqr - q0sqr) / (q0sqr * (1.0 + q0sqr))
    c = 1.0 / (1.0 + den2)
    c = jnp.clip(c, 0.0, 1.0)

    c_s = shift_clamp(c, -1, 0)   # c at south neighbour
    c_e = shift_clamp(c, -1, 1)   # c at east neighbour
    div = c_s * s + c * n + c_e * e + c * w
    return img + 0.25 * lam * div


def srad_q0sqr(img: jnp.ndarray):
    """The reduction feeding each SRAD iteration: q0² from mean/variance."""
    total = jnp.sum(img)
    total2 = jnp.sum(img * img)
    size = img.size
    mean = total / size
    var = (total2 / size) - mean * mean
    return var / (mean * mean)


def srad(img: jnp.ndarray, lam: float, steps: int) -> jnp.ndarray:
    for _ in range(steps):
        q0 = srad_q0sqr(img)
        img = srad_step(img, lam, q0)
    return img


# ---------------------------------------------------------------------------
# LUD (Rodinia dense linear algebra)
# ---------------------------------------------------------------------------

def lud(a: jnp.ndarray) -> jnp.ndarray:
    """Doolittle LU (no pivoting), combined L\\U matrix.

    Returns M where strict-lower(M) = L (unit diagonal implied) and
    upper(M) = U, matching Rodinia's in-place output layout.
    """
    a_np = np.array(a, dtype=np.float64)
    n = a_np.shape[0]
    for k in range(n):
        a_np[k + 1:, k] /= a_np[k, k]
        a_np[k + 1:, k + 1:] -= np.outer(a_np[k + 1:, k], a_np[k, k + 1:])
    return jnp.asarray(a_np.astype(np.float32))


def lud_diagonal(a: jnp.ndarray) -> jnp.ndarray:
    """LU-factorise a single (b, b) diagonal block (combined L\\U layout)."""
    return lud(a)


def lud_perimeter_row(diag_lu: jnp.ndarray, a_row: jnp.ndarray) -> jnp.ndarray:
    """Solve ``L_diag · U_row = A_row`` for U_row (unit-lower forward solve)."""
    lu = np.asarray(diag_lu)
    b = lu.shape[0]
    l = np.tril(lu, -1) + np.eye(b, dtype=np.float32)
    out = np.linalg.solve(l.astype(np.float64), np.asarray(a_row, np.float64))
    return jnp.asarray(out.astype(np.float32))


def lud_perimeter_col(diag_lu: jnp.ndarray, a_col: jnp.ndarray) -> jnp.ndarray:
    """Solve ``L_col · U_diag = A_col`` for L_col (upper back-substitution)."""
    lu = np.asarray(diag_lu)
    u = np.triu(lu)
    out = np.linalg.solve(
        u.astype(np.float64).T, np.asarray(a_col, np.float64).T
    ).T
    return jnp.asarray(out.astype(np.float32))


def lud_internal(c: jnp.ndarray, l_col: jnp.ndarray, u_row: jnp.ndarray):
    """Schur-complement update ``C -= L_col @ U_row`` (the GEMM hot spot)."""
    return c - l_col @ u_row


# ---------------------------------------------------------------------------
# Reductions
# ---------------------------------------------------------------------------

def sum_and_sumsq(x: jnp.ndarray):
    """SRAD's prepare+reduce fused: returns (sum(x), sum(x²))."""
    return jnp.sum(x), jnp.sum(x * x)
