"""L1 Pallas kernels for the dynamic-programming Rodinia benchmarks.

Pathfinder and Needleman-Wunsch carry loop dependencies that the thesis
resolves with FPGA registers / shift registers (§4.3.1.1, §4.3.1.4).  The
TPU adaptation turns those per-cycle register forwards into *vectorized
recurrences*:

* **Pathfinder**: the row-to-row dependency stays sequential (an in-kernel
  fused-rows loop — the analogue of the thesis's ``pyramid_height`` fused
  rows), while each row update is a radius-1 min-stencil over VPU lanes.
* **NW**: the thesis processes anti-diagonals with ``par`` cells per clock.
  Here each *row* is computed in one shot by recognising the left-neighbour
  recurrence ``s[j] = max(a[j], s[j-1] - p)`` as a max-plus prefix scan:
  with ``c[j] = a[j] + j*p`` it collapses to ``s[j] = cummax(c)[j] - j*p``,
  which vectorizes exactly (`lax.cummax`), so a block of n rows needs only
  an n-step ``fori_loop`` instead of n·m sequential cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def pathfinder_tile(width: int, fused_rows: int):
    """Build the Pathfinder fused-rows kernel.

    Input ``prev``: (width + 2*fused_rows,) i32 — the accumulated cost row
    with ``fused_rows`` halo cells per side (overlapped blocking, exactly
    the thesis's ``2*pyramid_height`` column overlap).
    Input ``wall``: (fused_rows, width + 2*fused_rows) i32 — the next
    ``fused_rows`` wall rows for the same span.
    Output: (width,) i32 — the accumulated cost after the fused rows,
    valid for the un-haloed interior.

    Roll wrap garbage is confined to the halo consumed per fused row; the
    *grid* boundary clamp is applied by the coordinator when it fills the
    halo of edge blocks.
    """
    padded = width + 2 * fused_rows

    def kernel(prev_ref, wall_ref, o_ref):
        acc = prev_ref[...]
        for t in range(fused_rows):
            left = jnp.roll(acc, 1)
            right = jnp.roll(acc, -1)
            acc = wall_ref[t, :] + jnp.minimum(jnp.minimum(left, acc), right)
        o_ref[...] = acc[fused_rows:padded - fused_rows]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((width,), jnp.int32),
        interpret=True,
    )


def nw_tile(rows: int, cols: int, penalty: int):
    """Build the NW block kernel (one (rows, cols) score block).

    Inputs:
      ``top``:  (cols,) i32 — score row directly above the block.
      ``left``: (rows,) i32 — score column directly left of the block.
      ``corner``: (1,) i32 — score at the top-left diagonal corner.
      ``ref_block``: (rows, cols) i32 — substitution scores for the block.
    Output: (rows, cols) i32 — the block's score matrix.

    Per-row max-plus prefix scan as described in the module docstring; the
    row loop is a ``fori_loop`` carrying (prev_row, prev_left_diag).
    """
    p = int(penalty)

    def kernel(top_ref, left_ref, corner_ref, refb_ref, o_ref):
        jidx = lax.iota(jnp.int32, cols)
        top = top_ref[...]
        left = left_ref[...]
        corner = corner_ref[0]
        refb = refb_ref[...]

        def row_step(i, carry):
            up, out = carry
            # diag[j] = score[i-1][j-1]: shift `up` right, seed from left/corner
            diag_seed = jnp.where(i == 0, corner, left[jnp.maximum(i - 1, 0)])
            diag = jnp.where(jidx == 0, diag_seed, jnp.roll(up, 1))
            a = jnp.maximum(diag + refb[i, :], up - p)
            # s[j] = max(a[j], s[j-1] - p) with s[-1] = left[i]
            c = a + jidx * p
            seed = left[i] - p  # c[-1]
            run = lax.cummax(jnp.maximum(c, jnp.where(jidx == 0, seed, -jnp.int32(2**30))))
            s = run - jidx * p
            out = out.at[i, :].set(s)
            return (s, out)

        out0 = jnp.zeros((rows, cols), dtype=jnp.int32)
        _, out = lax.fori_loop(0, rows, row_step, (top, out0))
        o_ref[...] = out

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=True,
    )
