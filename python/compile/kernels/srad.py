"""L1 Pallas kernels for SRAD (speckle-reducing anisotropic diffusion).

The thesis's advanced SRAD design (§4.3.1.5) merges Rodinia's six kernels
into one: a fused prepare+reduce pass and a fused two-pass stencil.  We
mirror that split as two pallas kernels:

* :func:`sum_sumsq_tile` — the fused prepare+reduce partial reduction for
  one tile (the coordinator combines partials, mirroring the shift-register
  reduction tree of §3.2.2.1).
* :func:`srad_tile` — both stencil passes fused on a VMEM tile.  Pass 1
  (radius 1) computes the diffusion coefficient, pass 2 (radius 1) applies
  the divergence; the fused halo is 2 per side per iteration, the same
  doubled halo the thesis uses for its merged-pass design.

``q0sqr`` is run-time data (the reduction result), so it enters as a (1,)
array operand rather than a baked constant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .stencil2d import clamp_restore2d, shift2d


def sum_sumsq_tile(tile_shape):
    """Partial reduction for one tile: out = [sum(x), sum(x*x)]."""

    def kernel(x_ref, o_ref):
        x = x_ref[...]
        o_ref[0] = jnp.sum(x)
        o_ref[1] = jnp.sum(x * x)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        interpret=True,
    )


def srad_tile(tile_shape, lam: float, steps: int = 1):
    """Fused two-pass SRAD update on one VMEM tile.

    Input tile carries ``h = 2*steps`` halo per side.  ``q0sqr`` is a (steps,)
    f32 operand (one reduction value per fused iteration).  Output is the
    interior ``tile[h:-h, h:-h]``.
    """
    lam = float(lam)
    ny, nx = tile_shape
    h = 2 * steps
    assert ny > 2 * h and nx > 2 * h
    out_shape = (ny - 2 * h, nx - 2 * h)

    def one_step(img: jnp.ndarray, q0: jnp.ndarray) -> jnp.ndarray:
        n = shift2d(img, 1, 0) - img
        s = shift2d(img, -1, 0) - img
        w = shift2d(img, 1, 1) - img
        e = shift2d(img, -1, 1) - img

        g2 = (n * n + s * s + w * w + e * e) / (img * img)
        l_ = (n + s + w + e) / img
        num = 0.5 * g2 - 0.0625 * (l_ * l_)
        den = 1.0 + 0.25 * l_
        qsqr = num / (den * den)

        den2 = (qsqr - q0) / (q0 * (1.0 + q0))
        c = jnp.clip(1.0 / (1.0 + den2), 0.0, 1.0)

        c_s = shift2d(c, -1, 0)
        c_e = shift2d(c, -1, 1)
        div = c_s * s + c * n + c_e * e + c * w
        return img + 0.25 * lam * div

    def kernel(img_ref, q0_ref, oob_ref, o_ref):
        img = img_ref[...]
        oob = oob_ref[...]
        for t in range(steps):
            img = clamp_restore2d(one_step(img, q0_ref[t]), oob)
        o_ref[...] = img[h:ny - h, h:nx - h]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        interpret=True,
    )
